"""Incremental shard-plan extension suite. Runs in a subprocess with 4
forced host devices.

Pins the PR-9 contract: ``planes.extend_plan`` must reproduce from-scratch
``shard_plan`` routing tables over random insert streams (bucket arrays
bit-identical on clean batches, slot decoding semantically identical
always), keep granule-rounded extents stable until a tail genuinely
overflows, early-out on zero-cut and empty-normalized batches, dedupe
in-batch duplicates/self-loops, keep EVERY raw slot over a multi-batch
rebuild catch-up window (insert -> delete -> re-insert of one pair must
route the live slot, not its tombstoned twin), extend the OVERRIDE plan
after an engine rebuild, and compile NOTHING for in-granule extensions —
while labels,
verdicts, and answers stay bitwise equal to the replicated oracle across
the full lifecycle (build -> insert stream -> delete -> rebuild).

Invoked by tests/test_plan_extension.py; exits non-zero on mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core import DBLIndex, make_graph  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.core import planes as PL  # noqa: E402
from repro.graphs.generators import power_law  # noqa: E402
from repro.serve.engine import QueryEngine  # noqa: E402

K = dict(k=16, k_prime=16, max_iters=64)
SHARDS = 4


def assert_index_eq(ref, idx, what):
    for name in ("dl_in", "dl_out", "bl_in", "bl_out", "landmarks",
                 "bl_sources", "bl_sinks"):
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(idx, name))
        assert (a == b).all(), f"{what}: {name} diverged"


def clean_batch(rng, n, b):
    """A random batch with no self-loops and no in-batch duplicates (the
    regime where extension must match from-scratch tables BIT for bit)."""
    ns = rng.integers(0, n, b).astype(np.int32)
    nd = ((ns + rng.integers(1, n, b)) % n).astype(np.int32)
    seen, keep = set(), np.ones(b, bool)
    for i, pair in enumerate(zip(ns.tolist(), nd.tolist())):
        if pair in seen:
            keep[i] = False
        seen.add(pair)
    return ns[keep], nd[keep]


def decoded_push(plan, dp):
    """(d, E_pad) global pushing-vertex id per bucket entry — the slot
    semantics (local row vs halo-buffer position) made order-independent,
    so plans whose halo lists differ only in ordering compare equal."""
    n_loc = plan.n_cap // plan.shards
    es = np.asarray(dp.e_slot)
    hs = np.asarray(dp.h_send)
    H = hs.shape[2]
    out = np.zeros_like(es, dtype=np.int64)
    for t in range(plan.shards):
        sl = es[t].astype(np.int64)
        local = sl < n_loc
        out[t][local] = t * n_loc + sl[local]
        off = sl[~local] - n_loc
        out[t][~local] = (off // H) * n_loc + hs[off // H, t, off % H]
    return out


def assert_plan_equiv(pe, ps, what, *, exact_buckets=True):
    """Extended plan == from-scratch plan: bucket arrays bit-identical
    (clean streams), halo routing semantically identical always."""
    assert pe.m == ps.m, (what, pe.m, ps.m)
    for dname in ("fwd", "bwd"):
        de, ds = getattr(pe, dname), getattr(ps, dname)
        if exact_buckets:
            assert de.e_recv.shape == ds.e_recv.shape, \
                (what, dname, de.e_recv.shape, ds.e_recv.shape)
            assert de.h_send.shape == ds.h_send.shape, \
                (what, dname, de.h_send.shape, ds.h_send.shape)
            for f in ("e_recv", "e_gid", "e_valid", "e_start", "e_tail"):
                a = np.asarray(getattr(de, f))
                b = np.asarray(getattr(ds, f))
                assert (a == b).all(), f"{what}: {dname}.{f} diverged"
        val = np.asarray(de.e_valid)
        a, b = decoded_push(pe, de), decoded_push(ps, ds)
        assert (a[val] == b[val]).all(), \
            f"{what}: {dname} slot decoding diverged"
        # halo lists: same vertex SETS per (sender, receiver) pair
        # (extension appends fresh vertices instead of re-sorting, so the
        # order may differ from the from-scratch globally-sorted lists)
        for s in range(SHARDS):
            for t in range(SHARDS):
                ae = np.asarray(de.h_send[s, t])[np.asarray(de.h_valid[s, t])]
                as_ = np.asarray(ds.h_send[s, t])[np.asarray(ds.h_valid[s, t])]
                assert set(ae.tolist()) == set(as_.tolist()), \
                    f"{what}: {dname} halo need set ({s}->{t}) diverged"
                assert len(ae) == len(set(ae.tolist())), \
                    f"{what}: {dname} halo list ({s}->{t}) has duplicates"


def plan_stream_equivalence():
    """Random insert stream, both granule regimes: default granules (tails
    absorb every batch — extents frozen) and tiny granules (repeated
    spills) — extended tables == from-scratch tables each round."""
    n, m0 = 256, 900
    src, dst = power_law(n, m0, seed=7)
    mesh = D.vertex_mesh(SHARDS)
    rng = np.random.default_rng(11)
    for eg, hg, rounds, what in ((1024, 64, 6, "in-granule"),
                                 (32, 4, 6, "spill")):
        gran = dict(edge_granule=eg, halo_granule=hg)
        plan = PL.shard_plan(src, dst, m0, n, mesh, **gran)
        e0 = (plan.fwd.e_recv.shape, plan.fwd.h_send.shape)
        asrc, adst = src, dst
        spilled = False
        for r in range(rounds):
            ns, nd = clean_batch(rng, n, int(rng.integers(8, 64)))
            plan = PL.extend_plan(plan, ns, nd, **gran)
            asrc = np.concatenate([asrc, ns])
            adst = np.concatenate([adst, nd])
            scratch = PL.shard_plan(asrc, adst, len(asrc), n, mesh, **gran)
            assert_plan_equiv(plan, scratch, f"{what} round {r}")
            spilled |= (plan.fwd.e_recv.shape, plan.fwd.h_send.shape) != e0
        if what == "in-granule":
            assert not spilled, "default granules spilled on a small stream"
        else:
            assert spilled, "tiny granules never spilled — overflow untested"
    print("plan stream equivalence OK")


def early_outs_and_dedupe():
    """Zero-cut batches reuse the halo arrays (object identity, not just
    equality); empty-normalized batches only advance m; duplicate pairs and
    self-loops never double-count in buckets or halo send lists."""
    n, m0 = 64, 200
    rng = np.random.default_rng(3)
    src = rng.integers(0, n, m0).astype(np.int32)
    dst = rng.integers(0, n, m0).astype(np.int32)
    mesh = D.vertex_mesh(SHARDS)
    plan = PL.shard_plan(src, dst, m0, n, mesh)
    n_loc = n // SHARDS

    # zero-cut: all new edges inside shard 0's rows [0, n_loc)
    ns = np.arange(0, n_loc - 1, dtype=np.int32)
    nd = ns + 1
    p2 = PL.extend_plan(plan, ns, nd)
    assert p2.m == plan.m + len(ns)
    for dname in ("fwd", "bwd"):
        de, d0 = getattr(p2, dname), getattr(plan, dname)
        assert de.h_send is d0.h_send and de.h_valid is d0.h_valid, \
            f"zero-cut batch copied the {dname} halo arrays"
    scratch = PL.shard_plan(np.concatenate([src, ns]),
                            np.concatenate([dst, nd]),
                            m0 + len(ns), n, mesh)
    assert_plan_equiv(p2, scratch, "zero-cut")

    # empty after normalization: self-loops + an in-batch duplicate pair
    ns = np.array([5, 5, 9], np.int32)
    nd = np.array([5, 5, 9], np.int32)
    p3 = PL.extend_plan(p2, ns, nd)
    assert p3.m == p2.m + 3, "raw batch size must advance m"
    assert p3.fwd.e_recv is p2.fwd.e_recv and p3.bwd.e_gid is p2.bwd.e_gid, \
        "empty-normalized batch rebuilt bucket arrays"

    # duplicates + self-loops mixed into a real batch: each surviving pair
    # appears EXACTLY once per direction bucket, first (lowest) gid kept
    ns = np.array([1, 1, 1, 17, 17, 40, 2, 2], np.int32)
    nd = np.array([33, 33, 33, 49, 49, 40, 60, 60], np.int32)
    p4 = PL.extend_plan(p3, ns, nd)
    assert p4.m == p3.m + len(ns)
    base = p3.m
    for dname in ("fwd", "bwd"):
        dp = getattr(p4, dname)
        gids = np.asarray(dp.e_gid)[np.asarray(dp.e_valid)]
        new = np.sort(gids[gids >= base])
        # kept slots: first occurrence of (1,33) at +0, (17,49) at +3,
        # (2,60) at +6; (40,40) is a self-loop, dropped
        assert new.tolist() == [base, base + 3, base + 6], \
            f"{dname}: dedupe kept wrong slots {new.tolist()} (base {base})"
    print("early-outs + dedupe OK")


def lifecycle_labels_bitwise():
    """The acceptance differential: replicated oracle vs sharded-with-
    extension vs sharded-from-scratch across build -> insert stream (with a
    duplicate/self-loop batch) -> delete -> delta rebuild -> insert -> full
    rebuild.  Labels bitwise equal at every step; queries equal at the end."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=5)
    mesh = D.vertex_mesh(SHARDS)
    rng = np.random.default_rng(21)

    g = make_graph(src, dst, n, m_cap=m + 1024)
    ref = DBLIndex.build(g, n_cap=n, **K)
    idx_e, plan_e = D.build_vertex_sharded(g, mesh, n_cap=n, **K)
    idx_s, plan_s = D.build_vertex_sharded(g, mesh, n_cap=n, **K)

    batches = [clean_batch(rng, n, 48) for _ in range(3)]
    # a hostile batch: duplicates + self-loops, raw (the graph keeps every
    # slot; only the routing tables dedupe)
    hostile = (np.array([7, 7, 7, 200, 13, 13], np.int32),
               np.array([190, 190, 190, 200, 77, 77], np.int32))
    batches.insert(2, hostile)
    for r, (ns, nd) in enumerate(batches):
        ref = ref.insert_edges(ns, nd, max_iters=64)
        idx_e, plan_e, _ = D.insert_vertex_sharded(idx_e, plan_e, ns, nd,
                                                   max_iters=64)
        idx_s, plan_s, _ = D.insert_vertex_sharded(idx_s, plan_s, ns, nd,
                                                   max_iters=64,
                                                   extend=False)
        assert plan_e.m == plan_s.m == int(np.asarray(idx_e.graph.m))
        assert_index_eq(ref, idx_e, f"extend insert {r}")
        assert_index_eq(ref, idx_s, f"scratch insert {r}")

    ds, dd = src[20:70], dst[20:70]
    ref = ref.delete_edges(ds, dd)
    idx_e = idx_e.delete_edges(ds, dd)
    refd = ref.rebuild(mode="delta", max_iters=64)
    idxd, pland, info = D.rebuild_vertex_sharded(idx_e, plan_e, mode="delta",
                                                 max_iters=64)
    assert info["mode"] == "delta", info
    assert_index_eq(refd, idxd, "delta rebuild")
    # the delta path hands back a compacted from-scratch plan; the next
    # insert extends IT
    ns, nd = clean_batch(rng, n, 24)
    refd = refd.insert_edges(ns, nd, max_iters=64)
    idxd, pland, _ = D.insert_vertex_sharded(idxd, pland, ns, nd,
                                             max_iters=64)
    assert_index_eq(refd, idxd, "post-delta extend insert")
    reff = refd.rebuild(mode="full", max_iters=64)
    idxf, _, _ = D.rebuild_vertex_sharded(idxd, pland, mode="full",
                                          max_iters=64)
    assert_index_eq(reff, idxf, "full rebuild")

    # stale-plan catch-up inside the delta rebuild path: hand it a plan
    # that misses the last insert window — it must extend, not misroute
    idx2, plan2 = D.build_vertex_sharded(g, mesh, n_cap=n, **K)
    ref2 = DBLIndex.build(g, n_cap=n, **K)
    ns, nd = clean_batch(rng, n, 32)
    idx2, plan_new, _ = D.insert_vertex_sharded(idx2, plan2, ns, nd,
                                                max_iters=64)
    ref2 = ref2.insert_edges(ns, nd, max_iters=64)
    ref2 = ref2.delete_edges(src[:10], dst[:10])
    idx2 = idx2.delete_edges(src[:10], dst[:10])
    refd2 = ref2.rebuild(mode="delta", max_iters=64)
    # pass the PRE-insert plan: plan2.m < m_now forces the catch-up branch
    idxd2, _, info2 = D.rebuild_vertex_sharded(idx2, plan2, mode="delta",
                                               max_iters=64)
    assert info2["mode"] == "delta", info2
    assert_index_eq(refd2, idxd2, "delta rebuild with stale plan")
    print("lifecycle labels bitwise OK")


def catchup_window_reinsert():
    """Regression (REVIEW high): the delta-rebuild catch-up window spans
    MULTIPLE insert batches, and a pair inserted, tombstoned, and
    re-inserted inside it has a dead slot with a lower gid than its live
    twin.  The per-batch first-occurrence dedupe would keep the dead slot
    (masked out every round via e_gid) and drop the live one — the edge
    would never relax and sharded labels would be silently wrong.  The
    catch-up must extend with dedupe=False: every raw slot routed, bucket
    arrays bit-identical to from-scratch, labels equal to the replicated
    oracle."""
    n, m = 256, 1200
    src, dst = power_law(n, m, seed=29)
    mesh = D.vertex_mesh(SHARDS)
    rng = np.random.default_rng(31)
    a, b = 3, n - 5                      # cross-shard pair (shard 0 -> 3)
    keep = ~((src == a) & (dst == b))    # not present in the base graph
    src, dst = src[keep], dst[keep]
    m0 = len(src)
    g = make_graph(src, dst, n, m_cap=m0 + 1024)
    ref = DBLIndex.build(g, n_cap=n, **K)
    idx, plan0 = D.build_vertex_sharded(g, mesh, n_cap=n, **K)

    # window batch 1 ends with (a, b); plan0 stays STALE on purpose
    ns1, nd1 = clean_batch(rng, n, 16)
    keep = ~((ns1 == a) & (nd1 == b))
    ns1 = np.concatenate([ns1[keep], [a]]).astype(np.int32)
    nd1 = np.concatenate([nd1[keep], [b]]).astype(np.int32)
    ref = ref.insert_edges(ns1, nd1, max_iters=64)
    idx, plan1, _ = D.insert_vertex_sharded(idx, plan0, ns1, nd1,
                                            max_iters=64)
    gid_dead = m0 + len(ns1) - 1
    # tombstone (a, b) — kills the batch-1 slot only
    da = np.array([a], np.int32)
    db = np.array([b], np.int32)
    ref = ref.delete_edges(da, db)
    idx = idx.delete_edges(da, db)
    # window batch 2 re-inserts (a, b): a NEW live slot, higher gid
    gid_live = int(np.asarray(idx.graph.m))
    ref = ref.insert_edges(da, db, max_iters=64)
    idx, plan2, _ = D.insert_vertex_sharded(idx, plan1, da, db,
                                            max_iters=64)
    m_now = int(np.asarray(idx.graph.m))

    # table-level pin: raw-slot extension over the whole window ==
    # from-scratch tables BIT for bit, with BOTH twins routed
    gsrc = np.asarray(idx.graph.src)
    gdst = np.asarray(idx.graph.dst)
    pext = PL.extend_plan(plan0, gsrc[plan0.m:m_now], gdst[plan0.m:m_now],
                          dedupe=False)
    scratch = PL.shard_plan(gsrc, gdst, m_now, n, mesh)
    assert_plan_equiv(pext, scratch, "catch-up window")
    for dname in ("fwd", "bwd"):
        dp = getattr(pext, dname)
        gids = set(np.asarray(dp.e_gid)[np.asarray(dp.e_valid)].tolist())
        assert gid_dead in gids and gid_live in gids, \
            f"{dname}: catch-up dropped a window slot " \
            f"(dead {gid_dead}, live {gid_live}, have {sorted(gids)[-8:]})"

    # end-to-end: delta rebuild handed the STALE plan0 must catch up over
    # the window and come out bitwise equal to the replicated oracle
    refd = ref.rebuild(mode="delta", max_iters=64)
    idxd, _, info = D.rebuild_vertex_sharded(idx, plan0, mode="delta",
                                             max_iters=64)
    assert info["mode"] == "delta", info
    assert_index_eq(refd, idxd, "catch-up reinsert delta rebuild")
    print("catch-up window re-insert OK")


def rebuild_insert_flush_ordering():
    """Engine ordering regression (satellite 3): after rebuild() hands the
    engine a fresh plan via _plan_override, an insert BEFORE the next flush
    must extend the override plan — not a stale one, and not pay a
    from-scratch rebuild.  Answers must match the replicated engine across
    submit -> delete -> rebuild -> insert -> submit -> flush."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=13)
    g = make_graph(src, dst, n, m_cap=m + 1024)
    mesh = D.vertex_mesh(SHARDS)
    ref = DBLIndex.build(g, n_cap=n, **K)
    eng_r = QueryEngine(ref, bfs_chunk=64, max_iters=64)
    eng_s = QueryEngine(ref, bfs_chunk=64, max_iters=64, vertex_mesh=mesh)
    rng = np.random.default_rng(17)

    u = rng.integers(0, n, 96).astype(np.int32)
    v = rng.integers(0, n, 96).astype(np.int32)
    p_r = eng_r.submit(eng_r.index, u, v)
    p_s = eng_s.submit(eng_s.index, u, v)
    eng_r.delete(src[:30], dst[:30])
    eng_s.delete(src[:30], dst[:30])
    eng_r.rebuild(mode="delta")
    eng_s.rebuild(mode="delta")
    assert eng_s._plan_override is None, "override leaked past the re-bind"
    adopted = eng_s._plan
    assert adopted.m == int(np.asarray(eng_s.index.graph.m)), \
        "adopted plan does not cover the rebuilt index"

    # insert BEFORE any flush: must extend the adopted override plan
    import repro.core.planes as planes_mod
    calls = {"n": 0}
    orig = planes_mod.shard_plan

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    planes_mod.shard_plan = counting
    try:
        ns, nd = clean_batch(rng, n, 24)
        eng_r.insert(ns, nd)
        eng_s.insert(ns, nd)
    finally:
        planes_mod.shard_plan = orig
    assert calls["n"] == 0, \
        "insert after rebuild paid a from-scratch plan rebuild"
    assert eng_s._plan.m == adopted.m + len(ns), \
        "insert did not extend the override plan"

    u2 = rng.integers(0, n, 96).astype(np.int32)
    v2 = rng.integers(0, n, 96).astype(np.int32)
    p_r2 = eng_r.submit(eng_r.index, u2, v2)
    p_s2 = eng_s.submit(eng_s.index, u2, v2)
    for a, b in zip(eng_r.flush([p_r, p_r2]), eng_s.flush([p_s, p_s2])):
        assert (np.asarray(a) == np.asarray(b)).all(), \
            "rebuild-then-insert-then-flush answers diverged"

    # a STALE override must be rejected at adoption, not trusted: plant one
    # for a different edge count and re-bind — the setter must rebuild
    eng_s._plan_override = eng_s._plan._replace(m=eng_s._plan.m + 999)
    eng_s.index = eng_s.index
    assert eng_s._plan_override is None
    assert eng_s._plan.m == int(np.asarray(eng_s.index.graph.m)), \
        "setter adopted a plan for the wrong edge prefix"
    u3 = rng.integers(0, n, 64).astype(np.int32)
    v3 = rng.integers(0, n, 64).astype(np.int32)
    assert (eng_r.query(u3, v3) == eng_s.query(u3, v3)).all()
    print("rebuild/insert/flush ordering OK")


def in_granule_extension_compiles_nothing():
    """Dispatch-shape budget: once the sharded engine is warm, a stream of
    in-granule inserts + queries + flushes adds ZERO compiled executables —
    neither engine phases nor the halo-fixpoint/seed-scatter impls (the
    extended plan keeps every operand shape, so jit caches never grow)."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=19)
    g = make_graph(src, dst, n, m_cap=m + 2048)
    mesh = D.vertex_mesh(SHARDS)
    ref = DBLIndex.build(g, n_cap=n, **K)
    eng = QueryEngine(ref, bfs_chunk=64, max_iters=64, vertex_mesh=mesh)
    eng.warmup(eng.index, bfs_buckets=eng._chunk_buckets())
    rng = np.random.default_rng(23)
    # one warm round: first insert/flush compiles the fixpoint shapes
    ns, nd = clean_batch(rng, n, 24)
    eng.insert(ns, nd)
    u = rng.integers(0, n, 96).astype(np.int32)
    v = rng.integers(0, n, 96).astype(np.int32)
    eng.flush([eng.submit(eng.index, u, v)])

    e_shape = (eng._plan.fwd.e_recv.shape, eng._plan.fwd.h_send.shape)
    warm = (eng.dispatch_shapes(),
            PL._halo_propagate_impl._cache_size(),
            PL.sharded_seed_scatter._cache_size())
    for r in range(4):
        ns, nd = clean_batch(rng, n, 24)
        eng.insert(ns, nd)
        u = rng.integers(0, n, 96).astype(np.int32)
        v = rng.integers(0, n, 96).astype(np.int32)
        pend = eng.submit(eng.index, u, v)
        (a,) = eng.flush([pend])
        assert a.shape == (96,)
    assert (eng._plan.fwd.e_recv.shape, eng._plan.fwd.h_send.shape) \
        == e_shape, "in-granule stream changed plan extents"
    now = (eng.dispatch_shapes(),
           PL._halo_propagate_impl._cache_size(),
           PL.sharded_seed_scatter._cache_size())
    assert now == warm, \
        f"in-granule extension stream recompiled: {warm} -> {now}"
    print("in-granule extension compiles nothing OK")


def main():
    assert len(jax.devices()) == 4, jax.devices()
    plan_stream_equivalence()
    early_outs_and_dedupe()
    lifecycle_labels_bitwise()
    catchup_window_reinsert()
    rebuild_insert_flush_ordering()
    in_granule_extension_compiles_nothing()
    print("PLAN_EXTENSION_OK")


if __name__ == "__main__":
    main()
