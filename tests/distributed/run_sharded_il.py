"""Vertex-sharded interval-family differential suite.  Runs in a
subprocess with 4 forced host devices: the ENTIRE sharded lifecycle of a
``families=("dl", "bl", "il")`` index — build, insert, delete, delta/full
rebuild, engine query stream — must be bitwise identical to the
replicated oracle, with the int32 rank planes row-partitioned and the
per-family prune telemetry agreeing across layouts.

Invoked by tests/test_families.py; exits non-zero on mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402

from repro.core import DBLIndex, make_graph  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.core import planes as PL  # noqa: E402
from repro.graphs.generators import power_law  # noqa: E402
from repro.serve.engine import QueryEngine  # noqa: E402

K = dict(k=16, k_prime=16, max_iters=64)
FAM = dict(families=("dl", "bl", "il"), il_dim=4, il_seed=7)


def eq(a, b, what):
    a, b = np.asarray(a), np.asarray(b)
    assert (a == b).all(), what


def check(ref, idx, what):
    for f in ("dl_in", "dl_out", "bl_in", "bl_out", "il_in", "il_out"):
        eq(getattr(ref, f), getattr(idx, f), f"{what}: {f} diverged")
    assert int(np.asarray(idx.il_seed)) == FAM["il_seed"]


def lifecycle():
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=3)
    g = make_graph(src, dst, n, m_cap=m + 512)
    mesh = D.vertex_mesh(4)
    ref = DBLIndex.build(g, n_cap=n, **K, **FAM)
    idx, plan = D.build_vertex_sharded(g, mesh, n_cap=n, **K, **FAM)
    check(ref, idx, "build")

    # placement contract: rank planes row-sharded like the bool planes
    sh = D.vertex_index_shardings(mesh, il=True)
    assert idx.il_in.sharding == sh.il_in
    assert idx.il_out.sharding == sh.il_out

    # sharded_il_rows: one-psum row reconstruction, exact for any sign
    rng = np.random.default_rng(0)
    u = rng.integers(0, n, 100).astype(np.int32)
    v = rng.integers(0, n, 100).astype(np.int32)
    rows = PL.sharded_il_rows(idx.il, u, v, mesh=mesh)
    for a, b in zip(rows, (ref.il_out[u], ref.il_out[v],
                           ref.il_in[u], ref.il_in[v])):
        eq(a, b, "sharded_il_rows")
    # the dead-lane sentinel n_cap is owned by no shard -> all-zero rows
    dead = np.full(4, n, np.int32)
    for r in PL.sharded_il_rows(idx.il, dead, dead, mesh=mesh):
        assert (np.asarray(r) == 0).all(), "sentinel rows must be zero"

    for r in range(3):
        ns = rng.integers(0, n, 32).astype(np.int32)
        nd = rng.integers(0, n, 32).astype(np.int32)
        ref = ref.insert_edges(ns, nd, max_iters=64)
        idx, plan, _ = D.insert_vertex_sharded(idx, plan, ns, nd,
                                               max_iters=64)
        check(ref, idx, f"insert round {r}")

    ref = ref.delete_edges(src[10:60], dst[10:60])
    idx = idx.delete_edges(src[10:60], dst[10:60])
    assert ref.is_dirty and idx.is_dirty

    refd = ref.rebuild(mode="delta", max_iters=64)
    idxd, pland, info = D.rebuild_vertex_sharded(idx, plan, mode="delta",
                                                 max_iters=64)
    assert info["mode"] == "delta"
    check(refd, idxd, "delta rebuild")
    reff = ref.rebuild(mode="full", max_iters=64)
    idxf, _, _ = D.rebuild_vertex_sharded(idx, plan, mode="full",
                                          max_iters=64)
    check(reff, idxf, "full rebuild")
    print("sharded IL lifecycle bitwise OK")


def engine_stream():
    n, m = 256, 1200
    src, dst = power_law(n, m, seed=9)
    g = make_graph(src, dst, n, m_cap=m + 1024)
    mesh = D.vertex_mesh(4)
    ref = DBLIndex.build(g, n_cap=n, **K, **FAM)
    eng_r = QueryEngine(ref, bfs_chunk=64, max_iters=64)
    eng_s = QueryEngine(ref, bfs_chunk=64, max_iters=64, vertex_mesh=mesh)
    rng = np.random.default_rng(4)
    pend_r, pend_s = [], []
    for r in range(6):
        u = rng.integers(0, n, 96).astype(np.int32)
        v = rng.integers(0, n, 96).astype(np.int32)
        eq(eng_r.query(u, v), eng_s.query(u, v), f"query round {r}")
        pend_r.append(eng_r.submit(eng_r.index, u, v))
        pend_s.append(eng_s.submit(eng_s.index, u, v))
        ns = rng.integers(0, n, 24).astype(np.int32)
        nd = rng.integers(0, n, 24).astype(np.int32)
        eng_r.insert(ns, nd)
        eng_s.insert(ns, nd)
        if r == 3:
            eng_r.delete(src[:20], dst[:20])
            eng_s.delete(src[:20], dst[:20])
    for a, b in zip(eng_r.flush(pend_r), eng_s.flush(pend_s)):
        eq(a, b, "flush parity")
    assert eng_r.stats.prune_hits == eng_s.stats.prune_hits, (
        eng_r.stats.prune_hits, eng_s.stats.prune_hits)
    assert eng_s.stats.prune_hits["il"] > 0, "IL never fired in the stream"
    i1 = eng_r.rebuild(mode="delta")
    i2 = eng_s.rebuild(mode="delta")
    check(i1, i2, "engine rebuild")
    u = rng.integers(0, n, 300).astype(np.int32)
    v = rng.integers(0, n, 300).astype(np.int32)
    eq(eng_r.query(u, v), eng_s.query(u, v), "post-rebuild queries")
    print("sharded IL engine stream parity OK")


if __name__ == "__main__":
    lifecycle()
    engine_stream()
    print("SHARDED_IL_OK")
