"""Multi-device DBL checks. Run in a subprocess with 8 host devices:
sharded build/query/insert must equal the single-logical-device results.

Invoked by test_distributed.py; exits non-zero on mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import DBLIndex, make_graph  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.graphs.generators import power_law  # noqa: E402
from repro.launch.mesh import make_mesh_compat  # noqa: E402
from repro.serve.engine import QueryEngine  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    n, m = 512, 4096
    src, dst = power_law(n, m, seed=3)
    m_cap = m + 64
    g = make_graph(src, dst, n, m_cap=m_cap)

    # single-device reference
    ref = DBLIndex.build(g, n_cap=n, k=16, k_prime=16, max_iters=64)

    mesh = make_mesh_compat((4, 2), ("data", "model"))
    idx = D.distributed_build(g, mesh, n_cap=n, k=16, k_prime=16,
                              max_iters=64)
    for name in ("dl_in", "dl_out", "bl_in", "bl_out"):
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(idx, name))
        assert (a == b).all(), f"sharded build diverged on {name}"

    rng = np.random.default_rng(0)
    u = rng.integers(0, n, 4096).astype(np.int32)
    v = rng.integers(0, n, 4096).astype(np.int32)
    verd_ref = np.asarray(ref.label_verdicts(u, v))
    verd_dist = np.asarray(D.distributed_label_verdicts(idx, mesh, u, v))
    assert (verd_ref == verd_dist).all(), "sharded verdicts diverged"

    ns = rng.integers(0, n, 64).astype(np.int32)
    nd = rng.integers(0, n, 64).astype(np.int32)
    ref2 = ref.insert_edges(ns, nd, max_iters=64)
    idx2 = D.distributed_insert(idx, mesh, ns, nd, max_iters=64)
    for name in ("dl_in", "dl_out", "bl_in", "bl_out"):
        a = np.asarray(getattr(ref2, name))
        b = np.asarray(getattr(idx2, name))
        assert (a == b).all(), f"sharded insert diverged on {name}"
    # device-resident contract: the sharded insert must come out in the
    # index sharding scheme (no host round-trip / re-device_put), with the
    # epoch a committed replicated int32 scalar
    want_sh = D.index_shardings(mesh)
    assert idx2.dl_in.sharding == want_sh.dl_in, idx2.dl_in.sharding
    assert idx2.graph.src.sharding == want_sh.graph.src
    assert idx2.packed.dl_in.sharding == want_sh.packed.dl_in
    assert idx2.epoch.dtype == jnp.int32 and int(idx2.epoch) == 1
    # a second batch reuses the cached executable and stays resident
    idx3b = D.distributed_insert(idx2, mesh, nd[:8], ns[:8], max_iters=64)
    assert idx3b.dl_in.sharding == want_sh.dl_in

    # fully-dynamic: sharded tombstone delete + dirty query + rebuild
    del_s, del_d = src[:32], dst[:32]
    refd = ref2.delete_edges(del_s, del_d)
    idxd = idx2.delete_edges(del_s, del_d)
    u2 = rng.integers(0, n, 1024).astype(np.int32)
    v2 = rng.integers(0, n, 1024).astype(np.int32)
    ad = np.asarray(refd.query(u2, v2, bfs_chunk=128, max_iters=64,
                               driver="host"))
    bd = np.asarray(idxd.query(u2, v2, bfs_chunk=128, max_iters=64,
                               driver="host"))
    assert (ad == bd).all(), "sharded dirty query diverged"
    refr = refd.rebuild(max_iters=64)
    br = np.asarray(refr.query(u2, v2, bfs_chunk=128, max_iters=64,
                               driver="host"))
    assert (ad == br).all(), "rebuild changed dirty-mode answers"

    # elastic re-placement: different mesh shape, same results
    mesh2 = make_mesh_compat((8,), ("data",))
    idx3 = D.shard_index(idx2, mesh2)
    verd3 = np.asarray(D.distributed_label_verdicts(idx3, mesh2, u, v))
    verd2 = np.asarray(ref2.label_verdicts(u, v))
    assert (verd3 == verd2).all(), "elastic re-placement diverged"

    # QueryEngine with query-axis sharding == single-device engine == host
    from repro.launch.sharding import reach_place_index
    eng = QueryEngine(bfs_chunk=128, max_iters=64, mesh=mesh2)
    placed = reach_place_index(ref2, mesh2)
    ans_sharded = eng.run(placed, u, v)
    ans_host = ref2.query(u, v, bfs_chunk=128, max_iters=64, driver="host")
    assert (ans_sharded == np.asarray(ans_host)).all(), \
        "sharded engine diverged from host driver"

    # vertex-sharded layout: label planes row-partitioned over all 8
    # devices (1/8th of the planes per device), served through the
    # all-gather-free engine — bitwise equal to the replicated reference
    from repro.core import planes as PL
    from repro.launch.sharding import reach_vertex_shardings
    vmesh = D.vertex_mesh(8)
    vidx, vplan = D.build_vertex_sharded(g, vmesh, n_cap=n, k=16,
                                         k_prime=16, max_iters=64)
    for name in ("dl_in", "dl_out", "bl_in", "bl_out"):
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(vidx, name))
        assert (a == b).all(), f"vertex-sharded build diverged on {name}"
    # sharding assertions: planes + packed words + leaf masks partitioned
    # along the vertex axis, graph replicated
    plane_sh, vec_sh, rep_sh = reach_vertex_shardings(vmesh)
    assert vidx.dl_in.sharding == plane_sh, vidx.dl_in.sharding
    assert vidx.packed.bl_out.sharding == plane_sh
    assert vidx.bl_sources.sharding == vec_sh
    assert vidx.graph.src.sharding == rep_sh
    assert PL.per_device_label_bytes(vidx) * 8 \
        == PL.per_device_label_bytes(ref)
    veng = QueryEngine(vidx, bfs_chunk=128, max_iters=64, vertex_mesh=vmesh)
    ans_vs = veng.query(u, v)
    ans_ref = ref.query(u, v, bfs_chunk=128, max_iters=64, driver="host")
    assert (ans_vs == np.asarray(ans_ref)).all(), \
        "vertex-sharded engine diverged from host driver"
    # sharded insert keeps the layout and the answers
    veng.insert(ns, nd)
    assert veng.index.dl_in.sharding == plane_sh
    ans_vs2 = veng.query(u, v)
    ans_ref2 = ref2.query(u, v, bfs_chunk=128, max_iters=64, driver="host")
    assert (ans_vs2 == np.asarray(ans_ref2)).all(), \
        "vertex-sharded post-insert query diverged"

    print("MULTIDEVICE_OK")


if __name__ == "__main__":
    main()
