"""Multi-device DBL checks. Run in a subprocess with 8 host devices:
sharded build/query/insert must equal the single-logical-device results.

Invoked by test_distributed.py; exits non-zero on mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import DBLIndex, make_graph  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.graphs.generators import power_law  # noqa: E402
from repro.launch.mesh import make_mesh_compat  # noqa: E402
from repro.serve.engine import QueryEngine  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    n, m = 512, 4096
    src, dst = power_law(n, m, seed=3)
    m_cap = m + 64
    g = make_graph(src, dst, n, m_cap=m_cap)

    # single-device reference
    ref = DBLIndex.build(g, n_cap=n, k=16, k_prime=16, max_iters=64)

    mesh = make_mesh_compat((4, 2), ("data", "model"))
    idx = D.distributed_build(g, mesh, n_cap=n, k=16, k_prime=16,
                              max_iters=64)
    for name in ("dl_in", "dl_out", "bl_in", "bl_out"):
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(idx, name))
        assert (a == b).all(), f"sharded build diverged on {name}"

    rng = np.random.default_rng(0)
    u = rng.integers(0, n, 4096).astype(np.int32)
    v = rng.integers(0, n, 4096).astype(np.int32)
    verd_ref = np.asarray(ref.label_verdicts(u, v))
    verd_dist = np.asarray(D.distributed_label_verdicts(idx, mesh, u, v))
    assert (verd_ref == verd_dist).all(), "sharded verdicts diverged"

    ns = rng.integers(0, n, 64).astype(np.int32)
    nd = rng.integers(0, n, 64).astype(np.int32)
    ref2 = ref.insert_edges(ns, nd, max_iters=64)
    idx2 = D.distributed_insert(idx, mesh, ns, nd, max_iters=64)
    for name in ("dl_in", "dl_out", "bl_in", "bl_out"):
        a = np.asarray(getattr(ref2, name))
        b = np.asarray(getattr(idx2, name))
        assert (a == b).all(), f"sharded insert diverged on {name}"
    # device-resident contract: the sharded insert must come out in the
    # index sharding scheme (no host round-trip / re-device_put), with the
    # epoch a committed replicated int32 scalar
    want_sh = D.index_shardings(mesh)
    assert idx2.dl_in.sharding == want_sh.dl_in, idx2.dl_in.sharding
    assert idx2.graph.src.sharding == want_sh.graph.src
    assert idx2.packed.dl_in.sharding == want_sh.packed.dl_in
    assert idx2.epoch.dtype == jnp.int32 and int(idx2.epoch) == 1
    # a second batch reuses the cached executable and stays resident
    idx3b = D.distributed_insert(idx2, mesh, nd[:8], ns[:8], max_iters=64)
    assert idx3b.dl_in.sharding == want_sh.dl_in

    # fully-dynamic: sharded tombstone delete + dirty query + rebuild
    del_s, del_d = src[:32], dst[:32]
    refd = ref2.delete_edges(del_s, del_d)
    idxd = idx2.delete_edges(del_s, del_d)
    u2 = rng.integers(0, n, 1024).astype(np.int32)
    v2 = rng.integers(0, n, 1024).astype(np.int32)
    ad = np.asarray(refd.query(u2, v2, bfs_chunk=128, max_iters=64,
                               driver="host"))
    bd = np.asarray(idxd.query(u2, v2, bfs_chunk=128, max_iters=64,
                               driver="host"))
    assert (ad == bd).all(), "sharded dirty query diverged"
    refr = refd.rebuild(max_iters=64)
    br = np.asarray(refr.query(u2, v2, bfs_chunk=128, max_iters=64,
                               driver="host"))
    assert (ad == br).all(), "rebuild changed dirty-mode answers"

    # elastic re-placement: different mesh shape, same results
    mesh2 = make_mesh_compat((8,), ("data",))
    idx3 = D.shard_index(idx2, mesh2)
    verd3 = np.asarray(D.distributed_label_verdicts(idx3, mesh2, u, v))
    verd2 = np.asarray(ref2.label_verdicts(u, v))
    assert (verd3 == verd2).all(), "elastic re-placement diverged"

    # QueryEngine with query-axis sharding == single-device engine == host
    from repro.launch.sharding import reach_place_index
    eng = QueryEngine(bfs_chunk=128, max_iters=64, mesh=mesh2)
    placed = reach_place_index(ref2, mesh2)
    ans_sharded = eng.run(placed, u, v)
    ans_host = ref2.query(u, v, bfs_chunk=128, max_iters=64, driver="host")
    assert (ans_sharded == np.asarray(ans_host)).all(), \
        "sharded engine diverged from host driver"

    print("MULTIDEVICE_OK")


if __name__ == "__main__":
    main()
