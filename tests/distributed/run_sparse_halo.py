"""Sparse compressed halo exchange differential suite.  Runs in a
subprocess with 4 forced host devices: the sparse changed-row exchange
(``core.halo``) must be bitwise identical to the dense halo oracle at the
``halo_propagate`` level (bool AND packed reprs, OR AND MIN monoids, with
and without the hub broadcast lane), across the ENTIRE sharded index
lifecycle (build -> hostile inserts incl. granule spills -> delete ->
delta rebuild), through the engine's sparse-mode insert/rebuild path, and
under the bucket-overflow dense fallback — while reporting strictly fewer
modeled halo bytes on converging streams.

Invoked by tests/test_sparse_halo.py; exits non-zero on mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import DBLIndex, make_graph  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.core import halo as HL  # noqa: E402
from repro.core import planes as PL  # noqa: E402
from repro.core.propagate import _INT_MAX  # noqa: E402
from repro.graphs.generators import power_law  # noqa: E402
from repro.launch.sharding import reach_halo_shardings  # noqa: E402
from repro.serve.engine import QueryEngine  # noqa: E402
from repro.serve.reach_server import ReachabilityServer  # noqa: E402

K = dict(k=16, k_prime=16, max_iters=64)


def assert_index_eq(ref, idx, what):
    for name in ("dl_in", "dl_out", "bl_in", "bl_out", "landmarks",
                 "bl_sources", "bl_sinks"):
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(idx, name))
        assert (a == b).all(), f"{what}: {name} diverged"
    for name in ("dl_in", "dl_out", "bl_in", "bl_out"):
        a = np.asarray(getattr(ref.packed, name))
        b = np.asarray(getattr(idx.packed, name))
        assert (a == b).all(), f"{what}: packed {name} diverged"


def _seed_planes(n, k, seeds):
    """A bool seed plane + matching int32 rank plane (negative seed values
    exercise the hub psum lane's exactness for MIN payloads < 0)."""
    plane = jnp.zeros((n, k), jnp.uint8).at[
        jnp.asarray(seeds), jnp.arange(len(seeds)) % k].set(1)
    ranks = np.full((n, k), _INT_MAX, np.int32)
    ranks[seeds, np.arange(len(seeds)) % k] = -(np.arange(len(seeds)) + 7)
    frontier = jnp.zeros((n,), jnp.bool_).at[jnp.asarray(seeds)].set(True)
    return plane, jnp.asarray(ranks), frontier


def halo_level_parity():
    """sparse == dense bitwise (labels AND iteration counts) for every
    repr/monoid/direction/hub combination, with the sparse run reporting
    strictly fewer modeled bytes; plus the reach_halo_shardings placement
    contract of the regime driver's accounting arrays."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=3)
    g = make_graph(src, dst, n, m_cap=m + 64)
    mesh = D.vertex_mesh(4)
    live = G.edge_mask(g)
    k = 20                                     # non-x32: pad-bit sweep
    seeds = np.arange(16, dtype=np.int32) * 15  # one per shard region
    plane, ranks, frontier = _seed_planes(n, k, seeds)
    sh = D.vertex_index_shardings(mesh)
    xs = jax.device_put(plane, sh.dl_in)
    rs = jax.device_put(ranks, sh.dl_in)
    for hub in (0, 8):
        plan = PL.shard_plan(g.src, g.dst, m, n, mesh, hub_count=hub)
        assert plan.hub_count == hub
        for monoid, repr_, x in (("or", "bool", xs), ("or", "packed", xs),
                                 ("min", "bool", rs)):
            for rev in (False, True):
                td, ts = HL.HaloTelemetry(), HL.HaloTelemetry()
                want, it_w = PL.halo_propagate(
                    plan, x, frontier, live, reverse=rev, max_iters=64,
                    monoid=monoid, plane_repr=repr_, telemetry=td)
                got, it_g = PL.halo_propagate(
                    plan, x, frontier, live, reverse=rev, max_iters=64,
                    monoid=monoid, plane_repr=repr_, halo_mode="sparse",
                    telemetry=ts)
                tag = (hub, monoid, repr_, rev)
                assert (np.asarray(got) == np.asarray(want)).all(), \
                    f"{tag}: sparse halo diverged from dense"
                assert int(it_g) == int(it_w), (tag, int(it_g), int(it_w))
                dd, ds = td.as_dict(), ts.as_dict()
                assert ds["halo_rounds"] == dd["halo_rounds"], tag
                assert ds["halo_bytes"] < dd["halo_bytes"], (
                    f"{tag}: sparse not cheaper: {ds} vs {dd}")
                assert ds["quiet_pair_rounds"] > 0, tag
        # zero-frontier entry: no rounds, no bytes, identity output
        t0 = HL.HaloTelemetry()
        zf = jnp.zeros((n,), jnp.bool_)
        out0, it0 = PL.halo_propagate(plan, xs, zf, live, max_iters=64,
                                      halo_mode="sparse", telemetry=t0)
        assert (np.asarray(out0) == np.asarray(plane)).all()
        assert int(it0) == 0 and t0.as_dict()["halo_bytes"] == 0
    # placement contract: the probe's (d, d) count matrix comes out
    # row-partitioned, the scalars replicated — exactly what
    # launch.sharding.reach_halo_shardings promises
    dp = plan.fwd
    dummy = jnp.zeros((1,), jnp.bool_)
    cnt, front, hub_any = HL._probe_impl(
        frontier, dp.h_send, dp.h_valid, dummy,
        jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
        mesh=mesh, use_hubs=False)
    pair_sh, repl_sh = reach_halo_shardings(mesh)
    assert cnt.sharding.is_equivalent_to(pair_sh, cnt.ndim), cnt.sharding
    assert front.sharding.is_equivalent_to(repl_sh, front.ndim)
    assert hub_any.sharding.is_equivalent_to(repl_sh, hub_any.ndim)
    print("halo-level parity OK")


def overflow_fallback_and_caps():
    """Bucket overflow = regime transition: under tiny capacities the wide
    early rounds MUST run dense and the converged tail sparse, bitwise
    equal throughout; an all-overflowing cap schedule degrades to pure
    dense; a capacity override threads end to end."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=5)
    g = make_graph(src, dst, n, m_cap=m + 64)
    mesh = D.vertex_mesh(4)
    live = G.edge_mask(g)
    plan = PL.shard_plan(g.src, g.dst, m, n, mesh)
    # wide frontier: every 2nd vertex seeds -> the early rounds' per-pair
    # changed-row counts exceed the tiny caps below (the power-law cut
    # concentrates on few high-degree rows, so the overflow threshold is
    # single digits, not O(H))
    seeds = np.arange(0, n, 2, dtype=np.int32)
    plane, _, frontier = _seed_planes(n, 16, seeds)
    xs = jax.device_put(plane, D.vertex_index_shardings(mesh).dl_in)
    want, it_w = PL.halo_propagate(plan, xs, frontier, live, max_iters=64)
    ts = HL.HaloTelemetry()
    got, it_g = PL.halo_propagate(plan, xs, frontier, live, max_iters=64,
                                  halo_mode="sparse", telemetry=ts,
                                  halo_caps=(2, 8))
    assert (np.asarray(got) == np.asarray(want)).all(), \
        "overflow fallback diverged from dense"
    assert int(it_g) == int(it_w)
    d = ts.as_dict()
    assert d["dense_rounds"] > 0, f"tiny caps never overflowed: {d}"
    assert d["sparse_rounds"] > 0, f"converged tail never sparse: {d}"
    assert d["halo_rounds"] == d["dense_rounds"] + d["sparse_rounds"] \
        + d["local_rounds"], d
    # the hub lane absorbs exactly the overflowing rows: the same stream
    # under the same caps with the top-8 cut rows on the broadcast lane
    # needs NO dense fallback round, and still matches bitwise
    ph = PL.shard_plan(g.src, g.dst, m, n, mesh, hub_count=8)
    th = HL.HaloTelemetry()
    goth, _ = PL.halo_propagate(ph, xs, frontier, live, max_iters=64,
                                halo_mode="sparse", telemetry=th,
                                halo_caps=(2, 8))
    assert (np.asarray(goth) == np.asarray(want)).all()
    dh = th.as_dict()
    assert dh["dense_rounds"] == 0 and dh["sparse_rounds"] > 0, dh
    assert dh["halo_bytes"] < d["halo_bytes"], (dh, d)
    # caps >= H are dropped by the sanitizer -> no sparse shapes at all ->
    # every round dense, still bitwise equal
    H = plan.fwd.h_send.shape[2]
    t2 = HL.HaloTelemetry()
    got2, _ = PL.halo_propagate(plan, xs, frontier, live, max_iters=64,
                                halo_mode="sparse", telemetry=t2,
                                halo_caps=(H, 4 * H))
    assert (np.asarray(got2) == np.asarray(want)).all()
    d2 = t2.as_dict()
    assert d2["sparse_rounds"] == 0 and d2["dense_rounds"] > 0, d2
    assert HL.bucket_caps(8) == ()
    assert HL.bucket_caps(64) == (8, 16)
    print("overflow fallback + caps override OK")


def degenerate_plans():
    """Degenerate shard plans under sparse mode: a cut-free (local-only)
    graph must run pure local-regime rounds (no payload collective, tiny
    byte count); a hub request on a graph with no cut-degree>=2 vertex
    must produce the all-padding hub table and still match; H=0 hubs on
    an empty-edge plan must not crash the probe."""
    mesh = D.vertex_mesh(4)
    n = 64
    rng = np.random.default_rng(12)
    # all edges inside shard 0's row range [0, 16): no cross-shard traffic
    src = rng.integers(0, 16, 80).astype(np.int32)
    dst = rng.integers(0, 16, 80).astype(np.int32)
    g = make_graph(src, dst, n, m_cap=128)
    live = G.edge_mask(g)
    from repro.core import propagate as PROP
    seeds = np.arange(12, dtype=np.int32)
    plane, _, frontier = _seed_planes(n, 16, seeds)
    want, it_w = PROP.propagate(plane, g.src, g.dst, live, frontier,
                                n_cap=n, max_iters=32)
    xs = jax.device_put(plane, D.vertex_index_shardings(mesh).dl_in)
    for hub in (0, 4):
        plan = PL.shard_plan(g.src, g.dst, len(src), n, mesh,
                             hub_count=hub)
        if hub:
            # no cut edges at all -> hub selection finds nothing; the
            # (hub,) table is pure n_cap padding, owned by no shard
            assert int(np.asarray(plan.fwd.h_valid).sum()) == 0
            assert (np.asarray(plan.fwd.hubs) == n).all()
        ts = HL.HaloTelemetry()
        got, it_g = PL.halo_propagate(plan, xs, frontier, live,
                                      max_iters=32, halo_mode="sparse",
                                      telemetry=ts)
        assert (np.asarray(got) == np.asarray(want)).all(), \
            f"hub={hub}: local-only sparse diverged"
        assert int(it_g) == int(it_w)
        d = ts.as_dict()
        assert d["local_rounds"] == d["halo_rounds"] > 0, d
        assert d["dense_rounds"] == 0 and d["sparse_rounds"] == 0, d
        assert d["halo_bytes"] == d["halo_rounds"] * 4 * 4, d
    # empty edge set: plan fabricates the dummy halo row; sparse entry
    # must return the identity immediately
    ge = make_graph(src[:0], dst[:0], n, m_cap=16)
    pe = PL.shard_plan(ge.src, ge.dst, 0, n, mesh, hub_count=4)
    oute, ite = PL.halo_propagate(pe, xs, frontier, G.edge_mask(ge),
                                  max_iters=32, halo_mode="sparse")
    assert (np.asarray(oute) == np.asarray(plane)).all()
    assert int(ite) in (0, 1)
    print("degenerate plans OK")


def lifecycle_sparse_differential():
    """The whole vertex-sharded lifecycle driven with halo_mode='sparse'
    (hubs on) == the replicated oracle bitwise, bool AND packed reprs:
    build -> hostile insert batches (new boundary vertices force halo
    granule spills and plan extension) -> delete -> delta rebuild ->
    post-delta insert."""
    n, m = 256, 1400
    # initial edges only among [0, 160): later batches hit fresh rows so
    # extend_plan must grow halo granules mid-stream
    rng = np.random.default_rng(7)
    src = rng.integers(0, 160, m).astype(np.int32)
    dst = rng.integers(0, 160, m).astype(np.int32)
    g = make_graph(src, dst, n, m_cap=m + 512)
    mesh = D.vertex_mesh(4)
    for repr_ in ("bool", "packed"):
        hk = dict(halo_mode="sparse", halo_caps=None)
        ref = DBLIndex.build(g, n_cap=n, **K)
        idx, plan = D.build_vertex_sharded(g, mesh, n_cap=n,
                                           plane_repr=repr_, hub_count=8,
                                           **hk, **K)
        assert plan.hub_count == 8
        assert_index_eq(ref, idx, f"{repr_} sparse build")
        for r in range(3):
            ns = rng.integers(0, n, 32).astype(np.int32)
            nd = rng.integers(0, n, 32).astype(np.int32)
            ref = ref.insert_edges(ns, nd, max_iters=64)
            idx, plan, _ = D.insert_vertex_sharded(
                idx, plan, ns, nd, max_iters=64, plane_repr=repr_, **hk)
            assert_index_eq(ref, idx, f"{repr_} sparse insert round {r}")
        # the spilled granules grew the halo: hubs must have survived the
        # extension with their receiver slots remapped, not dropped
        assert np.asarray(plan.fwd.hubs).shape[0] == 8
        ds, dd = src[10:60], dst[10:60]
        ref = ref.delete_edges(ds, dd)
        idx = idx.delete_edges(ds, dd)
        refd = ref.rebuild(mode="delta", max_iters=64)
        idxd, pland, info = D.rebuild_vertex_sharded(
            idx, plan, mode="delta", max_iters=64, plane_repr=repr_, **hk)
        assert info["mode"] == "delta", info
        assert_index_eq(refd, idxd, f"{repr_} sparse delta rebuild")
        ns = rng.integers(0, n, 16).astype(np.int32)
        nd = rng.integers(0, n, 16).astype(np.int32)
        refd2 = refd.insert_edges(ns, nd, max_iters=64)
        idxd2, _, _ = D.insert_vertex_sharded(
            idxd, pland, ns, nd, max_iters=64, plane_repr=repr_, **hk)
        assert_index_eq(refd2, idxd2, f"{repr_} post-delta sparse insert")
    print("lifecycle sparse differential OK")


def engine_telemetry_stream():
    """Engine-level: a sparse-mode sharded engine answers bitwise equal to
    a dense-mode one over a converging insert/query/rebuild stream while
    reporting the SAME halo round count but STRICTLY fewer halo bytes;
    the counters surface through engine.halo_stats() and
    ReachabilityServer.engine_stats()."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=9)
    g = make_graph(src, dst, n, m_cap=m + 1024)
    mesh = D.vertex_mesh(4)
    ref = DBLIndex.build(g, n_cap=n, **K)
    eng_d = QueryEngine(ref, bfs_chunk=64, max_iters=64, vertex_mesh=mesh)
    eng_s = QueryEngine(ref, bfs_chunk=64, max_iters=64, vertex_mesh=mesh,
                        halo_mode="sparse", hub_count=8)
    rng = np.random.default_rng(4)
    for r in range(6):
        ns = rng.integers(0, n, 24).astype(np.int32)
        nd = rng.integers(0, n, 24).astype(np.int32)
        eng_d.insert(ns, nd)
        eng_s.insert(ns, nd)
        u = rng.integers(0, n, 96).astype(np.int32)
        v = rng.integers(0, n, 96).astype(np.int32)
        assert (eng_d.query(u, v) == eng_s.query(u, v)).all(), r
    assert_index_eq(eng_d.index, eng_s.index, "engine sparse stream")
    eng_d.delete(src[:30], dst[:30])
    eng_s.delete(src[:30], dst[:30])
    i1 = eng_d.rebuild(mode="auto")
    i2 = eng_s.rebuild(mode="auto")
    assert_index_eq(i1, i2, "engine sparse rebuild")
    sd, ss = eng_d.halo_stats(), eng_s.halo_stats()
    assert ss["fixpoints"] == sd["fixpoints"] > 0, (sd, ss)
    assert ss["halo_rounds"] == sd["halo_rounds"] > 0, (sd, ss)
    assert 0 < ss["halo_bytes"] < sd["halo_bytes"], (sd, ss)
    assert ss["quiet_pair_rounds"] > 0
    assert sd["sparse_rounds"] == 0 and ss["sparse_rounds"] > 0
    # EngineStats mirror + server surfacing
    assert eng_s.stats.halo_bytes == ss["halo_bytes"]
    assert eng_s.stats.halo_rounds == ss["halo_rounds"]
    srv = ReachabilityServer(None, engine=eng_s)
    es = srv.engine_stats()
    assert es["halo"]["mode"] == "sparse"
    assert es["halo"]["hub_count"] == 8
    assert es["halo_bytes"] == ss["halo_bytes"]
    assert es["halo"]["sparse_rounds"] == ss["sparse_rounds"]
    print("engine telemetry stream OK")


def regime_hlo():
    """Compiled-HLO inspection of the regime kernels: the sparse regime's
    payload crosses the mesh via all-to-all (compacted buffers), never an
    all-gather; the local regime compiles to NO all-to-all at all — the
    zero-payload quiescent rounds really ship nothing but the liveness
    psum."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=1)
    g = make_graph(src, dst, n, m_cap=m + 64)
    mesh = D.vertex_mesh(4)
    live = G.edge_mask(g)
    plan = PL.shard_plan(g.src, g.dst, m, n, mesh, hub_count=8)
    dp = plan.fwd
    seeds = np.arange(8, dtype=np.int32)
    plane, _, frontier = _seed_planes(n, 16, seeds)
    xs = jax.device_put(plane, D.vertex_index_shardings(mesh).dl_in)
    it0 = jnp.zeros((), jnp.int32)
    texts = {}
    for kind, cap in (("sparse", 8), ("local", 0)):
        texts[kind] = HL._regime_impl.lower(
            xs, frontier, live, it0, dp.e_slot, dp.e_recv, dp.e_gid,
            dp.e_valid, dp.e_start, dp.e_tail, dp.h_send, dp.h_valid,
            dp.h_hub, dp.hubs, dp.hub_slot, mesh=mesh, max_iters=64,
            monoid="or", plane_repr="bool", k=16, kind=kind, cap=cap,
            lo=0, use_hubs=(kind == "sparse")).compile().as_text()
    assert "all-gather" not in texts["sparse"], \
        "sparse regime lowered to an all-gather"
    assert "all-to-all" in texts["sparse"], \
        "expected the compacted-bucket all-to-all in the sparse regime"
    assert "all-to-all" not in texts["local"], \
        "local regime still ships a payload collective"
    assert "all-gather" not in texts["local"]
    print("regime HLO OK")


def main():
    assert len(jax.devices()) == 4, jax.devices()
    halo_level_parity()
    overflow_fallback_and_caps()
    degenerate_plans()
    lifecycle_sparse_differential()
    engine_telemetry_stream()
    regime_hlo()
    print("SPARSE_HALO_OK")


if __name__ == "__main__":
    main()
