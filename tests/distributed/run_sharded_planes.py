"""Vertex-sharded PlaneStore differential suite. Runs in a subprocess with
4 forced host devices: the ENTIRE sharded lifecycle — build, insert,
delete, delta/full rebuild, sync + pipelined queries — must be bitwise
identical to the replicated oracle, with per-device label bytes at
1/shards and no all-gather anywhere in the compiled verdict path.

Invoked by tests/test_sharded_planes.py; exits non-zero on mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import DBLIndex, make_graph  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.core import planes as PL  # noqa: E402
from repro.graphs.generators import power_law  # noqa: E402
from repro.serve.engine import QueryEngine  # noqa: E402

K = dict(k=16, k_prime=16, max_iters=64)


def assert_index_eq(ref, idx, what):
    for name in ("dl_in", "dl_out", "bl_in", "bl_out", "landmarks",
                 "bl_sources", "bl_sinks"):
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(idx, name))
        assert (a == b).all(), f"{what}: {name} diverged"
    for name in ("dl_in", "dl_out", "bl_in", "bl_out"):
        a = np.asarray(getattr(ref.packed, name))
        b = np.asarray(getattr(idx.packed, name))
        assert (a == b).all(), f"{what}: packed {name} diverged"


def lifecycle_differential():
    """build -> inserts -> deletes -> delta rebuild -> more stream -> full
    rebuild, sharded == replicated bitwise at every step."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=3)
    g = make_graph(src, dst, n, m_cap=m + 512)
    mesh = D.vertex_mesh(4)
    ref = DBLIndex.build(g, n_cap=n, **K)
    idx, plan = D.build_vertex_sharded(g, mesh, n_cap=n, **K)
    assert_index_eq(ref, idx, "build")

    # per-device plane bytes: exactly 1/shards of the replicated planes
    rep_bytes = PL.per_device_label_bytes(ref)
    shard_bytes = PL.per_device_label_bytes(idx)
    assert shard_bytes * 4 == rep_bytes, (shard_bytes, rep_bytes)
    pk_bytes = sum(int(w.addressable_shards[0].data.nbytes)
                   for w in idx.packed)
    pk_rep = sum(int(np.asarray(w).nbytes) for w in ref.packed)
    assert pk_bytes * 4 == pk_rep, (pk_bytes, pk_rep)

    # placement contract
    sh = D.vertex_index_shardings(mesh)
    assert idx.dl_in.sharding == sh.dl_in
    assert idx.packed.bl_out.sharding == sh.packed.bl_out
    assert idx.bl_sources.sharding == sh.bl_sources

    rng = np.random.default_rng(0)
    for r in range(3):
        ns = rng.integers(0, n, 32).astype(np.int32)
        nd = rng.integers(0, n, 32).astype(np.int32)
        ref = ref.insert_edges(ns, nd, max_iters=64)
        idx, plan, _ = D.insert_vertex_sharded(idx, plan, ns, nd,
                                               max_iters=64)
        assert_index_eq(ref, idx, f"insert round {r}")

    ds, dd = src[10:60], dst[10:60]
    ref = ref.delete_edges(ds, dd)
    idx = idx.delete_edges(ds, dd)
    assert ref.is_dirty and idx.is_dirty
    u = rng.integers(0, n, 600).astype(np.int32)
    v = rng.integers(0, n, 600).astype(np.int32)
    a = ref.query(u, v, bfs_chunk=64, max_iters=64, driver="host")
    eng = QueryEngine(idx, bfs_chunk=64, max_iters=64, vertex_mesh=mesh)
    b = eng.query(u, v)
    assert (np.asarray(a) == b).all(), "dirty sharded query diverged"

    refd = ref.rebuild(mode="delta", max_iters=64)
    idxd, pland, info = D.rebuild_vertex_sharded(idx, plan, mode="delta",
                                                 max_iters=64)
    assert info["mode"] == "delta", info
    assert_index_eq(refd, idxd, "delta rebuild")
    reff = ref.rebuild(mode="full", max_iters=64)
    idxf, planf, info_f = D.rebuild_vertex_sharded(idx, plan, mode="full",
                                                   max_iters=64)
    assert_index_eq(reff, idxf, "full rebuild")
    # stream continues from the delta index (delta-upon-delta)
    ns = rng.integers(0, n, 16).astype(np.int32)
    nd = rng.integers(0, n, 16).astype(np.int32)
    refd2 = refd.insert_edges(ns, nd, max_iters=64)
    idxd2, _, _ = D.insert_vertex_sharded(idxd, pland, ns, nd, max_iters=64)
    assert_index_eq(refd2, idxd2, "post-delta insert")
    print("lifecycle differential OK")


def scc_merge_split_cascade():
    """Two chains merged into one big cross-shard SCC by inserted back
    edges, then split again by deletion + delta rebuild — the labels must
    track the replicated oracle bitwise through both cascades (this is the
    DAG-free claim under sharding: SCC maintenance never happens)."""
    n = 64
    chain = np.arange(n - 1, dtype=np.int32)
    src = chain
    dst = chain + 1
    g = make_graph(src, dst, n, m_cap=2 * n + 64)
    mesh = D.vertex_mesh(4)
    ref = DBLIndex.build(g, n_cap=n, **K)
    idx, plan = D.build_vertex_sharded(g, mesh, n_cap=n, **K)
    assert_index_eq(ref, idx, "chain build")
    # close the cycle: every vertex reaches every vertex (one giant SCC
    # spanning all four shards)
    back = (np.array([n - 1], np.int32), np.array([0], np.int32))
    ref = ref.insert_edges(*back, max_iters=128)
    idx, plan, _ = D.insert_vertex_sharded(idx, plan, *back, max_iters=128)
    assert_index_eq(ref, idx, "SCC merge")
    # split it again
    mid = (np.array([n // 2], np.int32), np.array([n // 2 + 1], np.int32))
    ref = ref.delete_edges(*mid)
    idx = idx.delete_edges(*mid)
    refd = ref.rebuild(mode="delta", max_iters=128)
    idxd, _, info = D.rebuild_vertex_sharded(idx, plan, mode="delta",
                                             max_iters=128)
    assert_index_eq(refd, idxd, "SCC split delta rebuild")
    print("SCC merge/split cascade OK")


def engine_stream_and_budget():
    """Pipelined sharded serving == replicated engine bitwise across a
    mixed submit/insert/delete/flush/rebuild stream, in both consistency
    modes, with a pinned dispatch-shape budget (steady-state inserts and
    plan rebuilds must not recompile)."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=9)
    g = make_graph(src, dst, n, m_cap=m + 1024)
    mesh = D.vertex_mesh(4)
    ref = DBLIndex.build(g, n_cap=n, **K)
    eng_r = QueryEngine(ref, bfs_chunk=64, max_iters=64)
    eng_s = QueryEngine(ref, bfs_chunk=64, max_iters=64, vertex_mesh=mesh)
    # pre-compile every BFS chunk bucket so the budget pin below measures
    # steady-state churn, not first-touch bucket compilation
    eng_s.warmup(eng_s.index, bfs_buckets=eng_s._chunk_buckets())
    rng = np.random.default_rng(4)
    pend_r, pend_s = [], []
    warm_shapes = None
    for r in range(8):
        u = rng.integers(0, n, 96).astype(np.int32)
        v = rng.integers(0, n, 96).astype(np.int32)
        assert (eng_r.query(u, v) == eng_s.query(u, v)).all(), r
        pend_r.append(eng_r.submit(eng_r.index, u, v))
        pend_s.append(eng_s.submit(eng_s.index, u, v))
        ns = rng.integers(0, n, 24).astype(np.int32)
        nd = rng.integers(0, n, 24).astype(np.int32)
        eng_r.insert(ns, nd)
        eng_s.insert(ns, nd)
        if r == 4:
            eng_r.delete(src[:20], dst[:20])
            eng_s.delete(src[:20], dst[:20])
        if r == 3:
            # steady state reached: later rounds must not compile anything
            for a, b in zip(eng_r.flush(pend_r), eng_s.flush(pend_s)):
                assert (a == b).all()
            pend_r, pend_s = [], []
            warm_shapes = eng_s.dispatch_shapes()
    for a, b in zip(eng_r.flush(pend_r), eng_s.flush(pend_s)):
        assert (a == b).all()
    assert eng_s.dispatch_shapes() == warm_shapes, (
        "sharded stream recompiled after warmup: "
        f"{warm_shapes} -> {eng_s.dispatch_shapes()}")
    i1 = eng_r.rebuild(mode="auto")
    i2 = eng_s.rebuild(mode="auto")
    assert_index_eq(i1, i2, "engine rebuild")
    assert eng_r.last_rebuild_info["mode"] == eng_s.last_rebuild_info["mode"]
    u = rng.integers(0, n, 300).astype(np.int32)
    v = rng.integers(0, n, 300).astype(np.int32)
    assert (eng_r.query(u, v) == eng_s.query(u, v)).all()
    # latest-consistency parity across an insert gap
    p_r = eng_r.submit(eng_r.index, u, v)
    p_s = eng_s.submit(eng_s.index, u, v)
    eng_r.insert(src[:8], dst[:8])
    eng_s.insert(src[:8], dst[:8])
    (a,) = eng_r.flush([p_r], consistency="latest")
    (b,) = eng_s.flush([p_s], consistency="latest")
    assert (a == b).all(), "latest-consistency parity"
    print("engine stream parity + dispatch budget OK")


def verdict_path_hlo_is_all_gather_free():
    """Compiled-HLO inspection: neither the fused label phase nor the
    coalesced verdict+BFS phase of a vertex-sharded engine may contain an
    all-gather — the row blocks cross shards via one reduce (psum) and the
    BFS halo via all-to-all, both O(Q·W)/O(cut), never O(n_cap·W)."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=1)
    g = make_graph(src, dst, n, m_cap=m + 64)
    mesh = D.vertex_mesh(4)
    idx, plan = D.build_vertex_sharded(g, mesh, n_cap=n, **K)
    eng = QueryEngine(idx, bfs_chunk=64, max_iters=64, vertex_mesh=mesh)
    qp = eng._granule
    label_txt = eng._label_phase.lower(
        idx.packed, idx.il, jnp.zeros(qp, jnp.int32),
        jnp.zeros(qp, jnp.int32), jnp.asarray(False)).compile().as_text()
    assert "all-gather" not in label_txt, \
        "label phase lowered to an all-gather"
    assert "all-reduce" in label_txt or "reduce-scatter" in label_txt, \
        "expected the single psum row reconstruction in the label phase"
    c = eng._bucket_for(16)
    extra = eng._coalesced_extra_args()
    coal_txt = eng._coal_phases[c].lower(
        idx.graph, idx.packed, idx.il, jnp.full((c,), n, jnp.int32),
        jnp.zeros((c,), jnp.int32),
        jnp.full((c,), 2**31 - 1, jnp.int32), jnp.asarray(False),
        *extra).compile().as_text()
    assert "all-gather" not in coal_txt, \
        "coalesced verdict+BFS phase lowered to an all-gather"
    assert "all-to-all" in coal_txt, \
        "expected the boundary-bit halo exchange in the BFS phase"
    print("verdict-path HLO all-gather-free OK")


def degenerate_halo_or_noop():
    """shard_plan on a graph with ZERO cut edges (every edge shard-local)
    must fabricate the dummy halo row — H rounds up to the halo granule,
    h_valid is all-False — and the halo fixpoint's all_to_all of that row
    must be an OR no-op: the sharded result (bool AND packed) matches the
    replicated fixpoint bitwise.  Same check for the fully-degenerate
    empty-edge plan (PR-7 satellite: degenerate extents)."""
    from repro.core import graph as G
    from repro.core import propagate as P
    n = 64
    mesh = D.vertex_mesh(4)
    # all edges inside shard 0's row range [0, 16): no cross-shard traffic
    rng = np.random.default_rng(12)
    src = rng.integers(0, 16, 80).astype(np.int32)
    dst = rng.integers(0, 16, 80).astype(np.int32)
    for m_used, what in ((len(src), "local-only"), (0, "empty")):
        g = make_graph(src[:m_used], dst[:m_used], n, m_cap=128)
        plan = PL.shard_plan(g.src, g.dst, m_used, n, mesh)
        for dp in (plan.fwd, plan.bwd):
            assert int(np.asarray(dp.h_valid).sum()) == 0, \
                f"{what}: fabricated halo row claims validity"
            assert dp.h_send.shape[2] == 64, \
                f"{what}: H not rounded to halo granule: {dp.h_send.shape}"
            # the recv-sorted bucket padding must carry the n_loc sentinel
            # (dropped by both the bool segment-max and the packed tail
            # scatter), never a real row id
            pads = np.asarray(dp.e_recv)[~np.asarray(dp.e_valid)]
            assert (pads == n // 4).all(), f"{what}: pad sentinel wrong"
        live = G.edge_mask(g)
        k = 20                                   # non-x32: pad-bit sweep
        seeds = np.arange(min(k, 16), dtype=np.int32)
        plane = jnp.zeros((n, k), jnp.uint8).at[
            jnp.asarray(seeds), jnp.arange(len(seeds)) % k].set(1)
        frontier = jnp.zeros((n,), jnp.bool_).at[jnp.asarray(seeds)].set(True)
        want, it_want = P.propagate(plane, g.src, g.dst, live, frontier,
                                    n_cap=n, max_iters=32)
        xs = jax.device_put(plane, D.vertex_index_shardings(mesh).dl_in)
        for repr_ in ("bool", "packed"):
            got, it_got = PL.halo_propagate(plan, xs, frontier, live,
                                            max_iters=32, plane_repr=repr_)
            assert (np.asarray(got) == np.asarray(want)).all(), \
                f"{what}/{repr_}: degenerate halo changed the fixpoint"
            assert int(it_got) == int(it_want), (what, repr_)
    print("degenerate halo OR no-op OK")


def packed_sharded_parity():
    """The packed word-plane halo fixpoint serves the WHOLE vertex-sharded
    lifecycle — build, insert stream, delete, delta rebuild — bitwise equal
    to the replicated bool oracle (k = k' = 16: packed halo rows are one
    word per row, 32x less boundary traffic than the bool plane rows)."""
    n, m = 256, 1400
    src, dst = power_law(n, m, seed=6)
    g = make_graph(src, dst, n, m_cap=m + 512)
    mesh = D.vertex_mesh(4)
    ref = DBLIndex.build(g, n_cap=n, **K)
    idx, plan = D.build_vertex_sharded(g, mesh, n_cap=n,
                                       plane_repr="packed", **K)
    assert_index_eq(ref, idx, "packed build")
    rng = np.random.default_rng(2)
    for r in range(2):
        ns = rng.integers(0, n, 32).astype(np.int32)
        nd = rng.integers(0, n, 32).astype(np.int32)
        ref = ref.insert_edges(ns, nd, max_iters=64)
        idx, plan, _ = D.insert_vertex_sharded(idx, plan, ns, nd,
                                               max_iters=64,
                                               plane_repr="packed")
        assert_index_eq(ref, idx, f"packed insert round {r}")
    ds, dd = src[5:45], dst[5:45]
    ref = ref.delete_edges(ds, dd)
    idx = idx.delete_edges(ds, dd)
    refd = ref.rebuild(mode="delta", max_iters=64)
    idxd, _, info = D.rebuild_vertex_sharded(idx, plan, mode="delta",
                                             max_iters=64,
                                             plane_repr="packed")
    assert info["mode"] == "delta", info
    assert_index_eq(refd, idxd, "packed delta rebuild")
    # engine serving on the packed-maintained sharded index
    eng = QueryEngine(idxd, bfs_chunk=64, max_iters=64, vertex_mesh=mesh,
                      plane_repr="packed")
    u = rng.integers(0, n, 300).astype(np.int32)
    v = rng.integers(0, n, 300).astype(np.int32)
    a = refd.query(u, v, bfs_chunk=64, max_iters=64, driver="host")
    assert (np.asarray(a) == eng.query(u, v)).all(), \
        "packed sharded engine query diverged"
    print("packed sharded lifecycle parity OK")


def main():
    assert len(jax.devices()) == 4, jax.devices()
    lifecycle_differential()
    scc_merge_split_cascade()
    engine_stream_and_budget()
    verdict_path_hlo_is_all_gather_free()
    degenerate_halo_or_noop()
    packed_sharded_parity()
    print("SHARDED_PLANES_OK")


if __name__ == "__main__":
    main()
