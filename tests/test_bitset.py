import numpy as np
import jax.numpy as jnp
from tests._hyp import given, settings, st

from repro.core import bitset


@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(k, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random((7, k)) < 0.4
    packed = bitset.pack(jnp.asarray(bits))
    assert packed.dtype == jnp.uint32
    assert packed.shape == (7, (k + 31) // 32)
    back = np.asarray(bitset.unpack(packed, k))
    np.testing.assert_array_equal(back, bits)


@given(st.integers(1, 130), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_set_ops_match_python_sets(k, seed):
    rng = np.random.default_rng(seed)
    a = rng.random((16, k)) < 0.3
    b = rng.random((16, k)) < 0.3
    pa, pb = bitset.pack(jnp.asarray(a)), bitset.pack(jnp.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(bitset.intersect_any(pa, pb)), (a & b).any(-1))
    np.testing.assert_array_equal(
        np.asarray(bitset.subset(pa, pb)), (~a | b).all(-1))
    np.testing.assert_array_equal(
        np.asarray(bitset.popcount(pa)), a.sum(-1))


def test_bit_row():
    k = 70
    idx = jnp.asarray([0, 31, 32, 69])
    rows = bitset.bit_row(k, idx)
    bits = np.asarray(bitset.unpack(rows, k))
    expect = np.zeros((4, k), bool)
    expect[np.arange(4), np.asarray(idx)] = True
    np.testing.assert_array_equal(bits, expect)
