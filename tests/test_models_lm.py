"""LM architecture smoke tests: reduced configs, forward + train step + decode
continuation exactness, for all five assigned transformer archs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import gemma2_27b, qwen15_05b, tinyllama_11b, \
    moonshot_v1_16b_a3b, arctic_480b
from repro.models.transformer import model as M

ARCHS = {
    "gemma2-27b": gemma2_27b.SMOKE,
    "qwen1.5-0.5b": qwen15_05b.SMOKE,
    "tinyllama-1.1b": tinyllama_11b.SMOKE,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.SMOKE,
    "arctic-480b": arctic_480b.SMOKE,
}


@pytest.mark.parametrize("name", list(ARCHS))
def test_forward_shapes_and_finite(name):
    cfg = ARCHS[name]
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, aux = M.forward(params, cfg, tokens)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", list(ARCHS))
def test_train_step_decreases_loss(name):
    cfg = ARCHS[name]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    targets = jnp.roll(tokens, -1, axis=1)

    @jax.jit
    def step(p):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, tokens, targets), has_aux=True)(p)
        p = jax.tree.map(lambda w, g: w - 0.5 * g, p, grads)
        return p, loss

    losses = []
    for _ in range(4):
        params, loss = step(params)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", list(ARCHS))
def test_prefill_decode_matches_forward(name):
    """prefill(S) + decode_step must equal the full forward at position S."""
    cfg = ARCHS[name]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                                cfg.vocab)
    full_logits, _ = M.forward(params, cfg, tokens)
    last, cache = M.prefill(params, cfg, tokens[:, :s], s_cache=s + 4)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full_logits[:, s - 1], np.float32),
                               rtol=2e-4, atol=2e-4)
    dec_logits, cache = M.decode_step(params, cfg, cache, tokens[:, s],
                                      jnp.int32(s))
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits[:, s], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_local_window_masks_differ_from_global():
    """gemma2 local layers must actually mask: widening the window changes
    the output on long sequences."""
    cfg = ARCHS["gemma2-27b"]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 32), 0, cfg.vocab)
    a, _ = M.forward(params, cfg, tokens)
    b, _ = M.forward(params, cfg.scaled(window=32), tokens)
    assert not np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
