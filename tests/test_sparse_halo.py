"""Sparse compressed halo exchange suite (the PR-10 sparse-halo CI step).

The differential assertions live in tests/distributed/run_sparse_halo.py
and run in a subprocess with XLA_FLAGS forcing 4 host devices: the sparse
changed-row exchange must be bitwise equal to the dense halo oracle in
every repr/monoid/hub combination and across the whole sharded lifecycle
(build, hostile inserts with granule spills, delete, delta rebuild),
fall back to dense rounds on bucket overflow, run cut-free plans on the
zero-payload local regime, report strictly fewer modeled halo bytes at
identical round counts through engine.halo_stats() and
ReachabilityServer.engine_stats(), and keep the sparse regime's payload
on all-to-all (no all-gather) with the local regime payload-free."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_sparse_halo_differential():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests/distributed/run_sparse_halo.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "SPARSE_HALO_OK" in out.stdout
