"""Multi-device equivalence tests (run in a subprocess so the main test
process keeps its single CPU device; dryrun.py owns the 512-device config)."""
import os
import subprocess
import sys
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_multidevice_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests/distributed/run_multidevice.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "MULTIDEVICE_OK" in out.stdout


@pytest.mark.slow
def test_moe_sharded_equivalence():
    """shard_map all-to-all MoE == pjit MoE (values AND grads, no-drop)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests/distributed/run_moe_sharded.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "MOE_SHARDED_OK" in out.stdout
